"""`ApproxSpace` — the single runtime object owning approximate memory.

One `ApproxSpace` owns everything the paper's runtime service needs across
train / serve / checkpoint:

  * **regions** — the exact/approximate partition of every state pytree it
    has seen, cached by treedef (region classification is a pure function of
    tree structure, so it is computed once per structure, not once per call);
  * **stats** — one unified event stream (`core.stats`), including the Pallas
    kernel counter vectors (`kernels.ops.MM_*` / `AT_*`), so fused-kernel
    repairs land in the same Table-3 analogue as the jnp-level mechanisms;
  * **the paper's two mechanisms** — `use(x)` (register mode, §3.3: repair at
    every consumption) and `scrub(tree)` (memory mode, §3.4: repair once at
    the origin, functional write-back);
  * **the simulation boundary** — `inject(tree, key)` is the only entry point
    through which simulated bit flips reach runtime state, and it returns /
    records the ground-truth flip count;
  * **step decorators** — `wrap_train_step` / `wrap_serve_step` install the
    boundary scrub so launch builders stay thin.

Functional/stateful split: every mechanism has a pure form (pass `stats`,
get `(value, stats')` back — safe under jit, this is what the step wrappers
use) and a convenience form (omit `stats`; the event deltas accumulate into
the space's host-side `self.stats`).  Never use the convenience form inside
a jitted function — it would capture tracers.

Mesh-native execution (README §Distributed repair): the space optionally
carries a device mesh + logical-axis rules (`use_mesh`).  Host-side calls of
`scrub` / `scrub_pages` / `scrub_with_reference` / `inject` dispatch
jit-compiled executables planned by `runtime.plan.RepairPlan` — traced once
per `(treedef, avals, shardings)`, donated buffers on request, per-shard
local repair under GSPMD with flip/repair counters reduced globally (counted
once, never per-replica).  Inside an enclosing jit the same tree functions
below inline into the caller's trace, so both paths share one definition of
repair.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import detect, injection as injection_lib
from ..core import regions as regions_lib
from ..core import rules as rules_lib
from ..core import stats as stats_lib
from .config import ApproxConfig, ScrubSchedule

__all__ = [
    "ApproxSpace", "scrub_tree", "scrub_pages_tree", "reference_scrub_tree",
    "inject_tree", "use_tensor",
]


def _is_approx_float(leaf, region) -> bool:
    return (
        region is regions_lib.Region.APPROX
        and hasattr(leaf, "dtype")
        and jnp.issubdtype(leaf.dtype, jnp.floating)
    )


def _is_repair_rules(rules: Any) -> bool:
    """Is ``rules`` a repair ``RuleSet`` (or raw (pattern, RepairRule)
    bindings) rather than a mesh sharding-rules table?"""
    if isinstance(rules, rules_lib.RuleSet):
        return True
    if isinstance(rules, (tuple, list)) and rules:
        return all(
            isinstance(e, (tuple, list)) and len(e) == 2
            and isinstance(e[1], rules_lib.RepairRule)
            for e in rules
        )
    return False


def _has_tracers(tree: Any) -> bool:
    """True when any leaf is a jax tracer — the caller is inside an enclosing
    jit, so the mechanism must inline into that trace instead of dispatching
    a host-side compiled executable."""
    return any(
        isinstance(leaf, jax.core.Tracer) for leaf in jax.tree.leaves(tree)
    )


# ---------------------------------------------------------------------------
# Pytree-level mechanism implementations (the legacy core.repair pytree
# functions are thin shims over these).
# ---------------------------------------------------------------------------


# (ruleset, treedef) -> (rule_tree, index_tree): the eager entry points'
# analogue of ApproxSpace._rule_cache.  Path matching is a pure function of
# (rule set, tree structure), so value-equal rule sets share entries; the
# population is bounded by distinct configs × state layouts in the process.
_ASSIGN_CACHE: Dict[Any, Tuple[Any, Any]] = {}


def _assignment_for(cfg: Any, tree: Any):
    """(ruleset, rule_tree, index_tree) for callers that did not pre-compute
    the per-leaf rule assignment (the legacy eager entry points) — cached by
    (ruleset, treedef) so per-call regex matching never lands on a hot
    path."""
    ruleset = rules_lib.ruleset_of(cfg)
    try:
        key = (ruleset, jax.tree_util.tree_structure(tree))
        hit = _ASSIGN_CACHE.get(key)
    except TypeError:               # unhashable custom fill — skip the cache
        key, hit = None, None
    if hit is None:
        hit = ruleset.assign(tree)
        if key is not None:
            _ASSIGN_CACHE[key] = hit
    return ruleset, hit[0], hit[1]


def _finish_rule_counts(rc: jax.Array) -> jax.Array:
    """Append the per-rule events column: one pass with ≥1 fatal lane under
    rule i is one event for rule i (the per-rule Table-3 analogue)."""
    events = ((rc[:, 0] + rc[:, 1]) > 0).astype(jnp.int32)[:, None]
    return jnp.concatenate([rc, events], axis=1)


def scrub_tree_rules(
    tree: Any,
    cfg: Any,                       # ApproxConfig or legacy RepairConfig
    stats: stats_lib.Stats,
    region_tree: Any,
    rule_tree: Any,
    index_tree: Any,
    n_rules: int,
    trigger: str = "forced",
) -> Tuple[Any, stats_lib.Stats, jax.Array]:
    """Rule-parameterized memory-mode repair: every approximate-region float
    leaf is repaired under ITS assigned ``RepairRule`` (detector + fill),
    gated by the rule's trigger against this pass's ``trigger`` tag.

    Returns ``(tree', stats', rule_counts)`` where ``rule_counts`` is
    int32[n_rules, 3] = per-rule [nan, inf, events] deltas for this pass —
    the per-rule counters the space folds into its unified ledger.
    """
    if cfg.mode != "memory":
        return tree, stats, jnp.zeros((n_rules, 3), jnp.int32)

    nan_tot = jnp.zeros((), jnp.int32)
    inf_tot = jnp.zeros((), jnp.int32)
    rc = jnp.zeros((n_rules, 2), jnp.int32)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    region_leaves = jax.tree.leaves(region_tree)
    rule_leaves = jax.tree.leaves(rule_tree)
    index_leaves = jax.tree.leaves(index_tree)
    assert len(leaves) == len(region_leaves) == len(rule_leaves), (
        "region/rule tree structure mismatch"
    )

    fixed_leaves = []
    for leaf, region, rule, idx in zip(
        leaves, region_leaves, rule_leaves, index_leaves
    ):
        if _is_approx_float(leaf, region) and rule.fires(trigger):
            fixed, n, i = rule.apply(leaf)
            nan_tot = nan_tot + n
            inf_tot = inf_tot + i
            rc = rc.at[idx, 0].add(n).at[idx, 1].add(i)
            fixed_leaves.append(fixed)
        else:
            fixed_leaves.append(leaf)

    out = jax.tree_util.tree_unflatten(treedef, fixed_leaves)
    return (
        out,
        stats_lib.record_repair(stats, nan_tot, inf_tot),
        _finish_rule_counts(rc),
    )


def scrub_tree(
    tree: Any,
    cfg: Any,                       # ApproxConfig or legacy RepairConfig
    stats: stats_lib.Stats,
    region_tree: Any,
    *,
    trigger: str = "forced",
) -> Tuple[Any, stats_lib.Stats]:
    """Memory-mode repair of every approximate-region float leaf of ``tree``.

    The returned tree *replaces* the resident state (functional write-back;
    in-place under jit with donated buffers).  Exact-region and non-float
    leaves pass through untouched.  No-op outside memory mode.

    Repair semantics come from the config's ``RuleSet`` (README §RepairRule):
    a legacy scalar config lifts to one catch-all rule, reproducing the
    pre-rules behavior bit for bit.  Per-rule counters are dropped here —
    use ``ApproxSpace.scrub`` (or ``scrub_tree_rules``) to collect them.
    """
    ruleset, rule_tree, index_tree = _assignment_for(cfg, tree)
    out, stats, _ = scrub_tree_rules(
        tree, cfg, stats, region_tree, rule_tree, index_tree,
        ruleset.n_rules, trigger,
    )
    return out, stats


def scrub_pages_tree_rules(
    tree: Any,
    page_ids: jax.Array,            # i32[n] rows of the leading (page) axis
    cfg: Any,                       # ApproxConfig or legacy RepairConfig
    stats: stats_lib.Stats,
    region_tree: Any,
    rule_tree: Any,
    index_tree: Any,
    n_rules: int,
    trigger: str = "forced",
    n_valid: Optional[jax.Array] = None,
) -> Tuple[Any, stats_lib.Stats, jax.Array]:
    """Rule-parameterized page scrub: rows ``page_ids`` of each leaf are
    repaired under the leaf's assigned rule (detector + fill), gated by the
    rule's trigger.  Returns ``(tree', stats', rule_counts)`` —
    see ``scrub_tree_rules`` for the counts layout and ``scrub_pages_tree``
    for the page semantics."""
    if cfg.mode != "memory":
        return tree, stats, jnp.zeros((n_rules, 3), jnp.int32)
    page_ids = jnp.asarray(page_ids, jnp.int32)

    nan_tot = jnp.zeros((), jnp.int32)
    inf_tot = jnp.zeros((), jnp.int32)
    rc = jnp.zeros((n_rules, 2), jnp.int32)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    region_leaves = jax.tree.leaves(region_tree)
    rule_leaves = jax.tree.leaves(rule_tree)
    index_leaves = jax.tree.leaves(index_tree)
    assert len(leaves) == len(region_leaves) == len(rule_leaves), (
        "region/rule tree structure mismatch"
    )

    valid = None
    if n_valid is not None:
        valid = jnp.arange(page_ids.shape[0]) < n_valid

    fixed_leaves = []
    for leaf, region, rule, idx in zip(
        leaves, region_leaves, rule_leaves, index_leaves
    ):
        if _is_approx_float(leaf, region) and rule.fires(trigger):
            rows = leaf[page_ids]
            nan_m, inf_m = rule.detect.masks(rows)
            mask = nan_m | inf_m
            fixed = jnp.where(mask, rule.resolved_fill()(rows, mask), rows)
            if valid is not None:
                vshape = (rows.shape[0],) + (1,) * (rows.ndim - 1)
                nan_m = nan_m & valid.reshape(vshape)
                inf_m = inf_m & valid.reshape(vshape)
            n = jnp.sum(nan_m.astype(jnp.int32))
            i = jnp.sum(inf_m.astype(jnp.int32))
            nan_tot = nan_tot + n
            inf_tot = inf_tot + i
            rc = rc.at[idx, 0].add(n).at[idx, 1].add(i)
            fixed_leaves.append(leaf.at[page_ids].set(fixed.astype(leaf.dtype)))
        else:
            fixed_leaves.append(leaf)

    out = jax.tree_util.tree_unflatten(treedef, fixed_leaves)
    return (
        out,
        stats_lib.record_repair(stats, nan_tot, inf_tot),
        _finish_rule_counts(rc),
    )


def scrub_pages_tree(
    tree: Any,
    page_ids: jax.Array,            # i32[n] rows of the leading (page) axis
    cfg: Any,                       # ApproxConfig or legacy RepairConfig
    stats: stats_lib.Stats,
    region_tree: Any,
    n_valid: Optional[jax.Array] = None,
    *,
    trigger: str = "forced",
) -> Tuple[Any, stats_lib.Stats]:
    """Targeted memory-mode repair: only rows ``page_ids`` along the LEADING
    axis of every approximate-region float leaf are repaired and written back
    (functional ``.at[pages].set``).  This is the page-granular half of the
    paper's reactive design — scrub exactly the pages that faulted instead of
    the whole resident tree.  Duplicate page ids are idempotent (the same
    repaired rows are written twice).  No-op outside memory mode.

    ``n_valid`` supports the compiled bucketed path (``RepairPlan``): entries
    ``page_ids[n_valid:]`` are padding duplicates of real ids — their rows
    are still *repaired* (duplicate scatter writes must carry identical
    values to stay deterministic) but they are masked out of the lane
    counts, so padded and unpadded calls report identical stats.

    The caller guarantees every approximate float leaf shares one leading
    page axis (the serving KV pool layout, ``Model.paged_cache_defs``).
    Repair semantics per leaf come from the config's ``RuleSet``
    (README §RepairRule); legacy scalar configs lift to one catch-all rule.
    """
    ruleset, rule_tree, index_tree = _assignment_for(cfg, tree)
    out, stats, _ = scrub_pages_tree_rules(
        tree, page_ids, cfg, stats, region_tree, rule_tree, index_tree,
        ruleset.n_rules, trigger, n_valid,
    )
    return out, stats


def use_tensor(
    x: jax.Array,
    cfg: Any,                       # ApproxConfig or legacy RepairConfig
    stats: stats_lib.Stats,
    path: str = "",
) -> Tuple[jax.Array, stats_lib.Stats]:
    """Register-mode read (§3.3): repair at the consumption site.

    Identity outside register mode (memory mode relies on the scrubbed
    buffer, so per-use work would be pure overhead — exactly the paper's
    argument for the memory-repairing mechanism) — with ONE exception: a
    bound *on-read* rule requests use-site repair explicitly, so it fires
    in memory mode too (its leaves are skipped by every scheduled scrub;
    use() is their only repair point).  Pure; safe under jit.

    ``path`` names the parameter being read (nn layers annotate their
    reads, e.g. ``"layers/attn/wq"``): the ruleset binds the EXACT rule
    for that path (same first-match-wins patterns the scheduled scrubs
    assign by), so an on-read rule scoped to one parameter fires only
    there.  A pathless read keeps the ruleset's *read rule* (the first
    on-read rule, else the first non-exact rule — the one-rule legacy
    lift reproduces the scalar knobs exactly).  An exact-island match is
    the identity: its leaves are never repaired, use-site included.
    """
    if cfg.mode == "off":
        return x, stats
    ruleset = rules_lib.ruleset_of(cfg)
    rule = ruleset.rule_for(path)[1] if path else ruleset.read_rule()
    if rule.exact:
        return x, stats
    if cfg.mode != "register" and rule.trigger != "on-read":
        return x, stats
    fixed, n, i = rule.apply(x)
    return fixed, stats_lib.record_repair(stats, n, i)


def reference_scrub_tree_rules(
    tree: Any,
    ref_tree: Any,
    stats: stats_lib.Stats,
    region_tree: Any,
    rule_tree: Any,
    index_tree: Any,
    n_rules: int,
) -> Tuple[Any, stats_lib.Stats, jax.Array]:
    """Rule-parameterized ``last_checkpoint`` repair: each leaf's fatal
    lanes — as defined by ITS rule's detector — are replaced from
    ``ref_tree``.  A reference repair is a forced pass: every non-exact
    rule fires regardless of its trigger (a checkpoint-backed repair is
    always an explicit request).  Returns ``(tree', stats', rule_counts)``.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    refs = jax.tree.leaves(ref_tree)
    regs = jax.tree.leaves(region_tree)
    rule_leaves = jax.tree.leaves(rule_tree)
    index_leaves = jax.tree.leaves(index_tree)
    assert len(leaves) == len(refs) == len(regs), "treedef mismatch"

    nan_tot = jnp.zeros((), jnp.int32)
    inf_tot = jnp.zeros((), jnp.int32)
    rc = jnp.zeros((n_rules, 2), jnp.int32)
    out = []
    for leaf, ref, region, rule, idx in zip(
        leaves, refs, regs, rule_leaves, index_leaves
    ):
        if _is_approx_float(leaf, region) and rule.fires("forced"):
            nan_m, inf_m = rule.detect.masks(leaf)
            mask = nan_m | inf_m
            out.append(jnp.where(mask, jnp.asarray(ref, leaf.dtype), leaf))
            n = jnp.sum(nan_m.astype(jnp.int32))
            i = jnp.sum(inf_m.astype(jnp.int32))
            nan_tot = nan_tot + n
            inf_tot = inf_tot + i
            rc = rc.at[idx, 0].add(n).at[idx, 1].add(i)
        else:
            out.append(leaf)
    return (
        jax.tree_util.tree_unflatten(treedef, out),
        stats_lib.record_repair(stats, nan_tot, inf_tot),
        _finish_rule_counts(rc),
    )


def reference_scrub_tree(
    tree: Any,
    ref_tree: Any,
    stats: stats_lib.Stats,
    region_tree: Any,
    *,
    include_inf: bool = True,
    cfg: Any = None,
) -> Tuple[Any, stats_lib.Stats]:
    """``last_checkpoint`` repair (README §Policies): replace fatal lanes of
    approximate-region leaves with the values from ``ref_tree`` (same
    treedef, e.g. the latest checkpoint) — exact restoration for frozen
    weights, one checkpoint interval of optimizer drift otherwise.

    Unlike ``scrub_tree`` this is NOT gated on the repair mode: a reference
    repair is always an explicit request (checkpoint restore, periodic
    reference pass) and must run even in register-mode or off deployments.

    With ``cfg`` (an ``ApproxConfig``/``RepairConfig``) the per-leaf
    detectors come from its ``RuleSet``; the bare ``include_inf`` form keeps
    the legacy NaN/Inf definition for shim callers.
    """
    if cfg is not None:
        ruleset, rule_tree, index_tree = _assignment_for(cfg, tree)
    else:
        ruleset = rules_lib.RuleSet.single(
            rules_lib.RepairRule(
                detect=rules_lib.Detector(nan=True, inf=include_inf)
            )
        )
        rule_tree, index_tree = ruleset.assign(tree)
    out, stats, _ = reference_scrub_tree_rules(
        tree, ref_tree, stats, region_tree, rule_tree, index_tree,
        ruleset.n_rules,
    )
    return out, stats


def _leaf_flip_count(before: jax.Array, after: jax.Array) -> jax.Array:
    """Ground-truth bits-flipped between two same-shape float arrays."""
    delta = detect.bits_of(before) ^ detect.bits_of(after)
    return jnp.sum(
        jax.lax.population_count(delta).astype(jnp.int32)
    )


def inject_tree(
    tree: Any,
    key: jax.Array,
    ber: float,
    region_tree: Any,
) -> Tuple[Any, jax.Array]:
    """One approximate-memory window of bit flips over the approximate-region
    leaves (simulation only).  Returns ``(flipped_tree, n_flips)`` where
    ``n_flips`` is the ground-truth number of bits that actually changed
    (collisions fold by XOR, exactly as two physical flips would)."""
    zero = jnp.zeros((), jnp.int32)
    if ber <= 0.0:
        return tree, zero

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    region_leaves = jax.tree.leaves(region_tree)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    flips = zero
    for leaf, region, k in zip(leaves, region_leaves, keys):
        if _is_approx_float(leaf, region):
            flipped = injection_lib.flip_bits(k, leaf, ber)
            flips = flips + _leaf_flip_count(leaf, flipped)
            out.append(flipped)
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out), flips


# ---------------------------------------------------------------------------
# The space.
# ---------------------------------------------------------------------------


class ApproxSpace:
    """The runtime service over one approximate-memory deployment.

    Construct from an ``ApproxConfig``, a legacy ``RepairConfig``, or field
    overrides::

        space = ApproxSpace(ApproxConfig(mode="memory", policy="zero"))
        space = ApproxSpace(model.cfg.repair)          # legacy lift
        space = ApproxSpace(mode="register")           # field shorthand
        space = ApproxSpace(mode="memory", rules=RuleSet(...))  # repair rules

    The ``rules`` keyword is overloaded for ergonomics: a ``RuleSet`` (or
    raw ``(pattern, RepairRule)`` bindings) is a *repair*-rules config
    override; anything else is the mesh's logical-axis *sharding* rules
    table and only meaningful together with ``mesh``.
    """

    def __init__(
        self,
        config: Any = None,
        *,
        mesh: Any = None,
        rules: Any = None,
        **overrides,
    ):
        sharding_rules = rules
        if rules is not None and _is_repair_rules(rules):
            # a repair RuleSet must never be silently captured by the
            # sharding-rules slot — route it into the config override
            overrides["rules"] = rules
            sharding_rules = None
        if config is None:
            config = ApproxConfig(**overrides)
        else:
            config = ApproxConfig.from_legacy(config, **overrides)
        self.config: ApproxConfig = config
        self.stats: stats_lib.Stats = stats_lib.zeros()
        self.scrubbed_bytes: int = 0     # host ledger: approx bytes processed
        self._region_cache: Dict[Any, Any] = {}
        # per-leaf RepairRule assignment cache: treedef -> (rules, indices)
        self._rule_cache: Dict[Any, Any] = {}
        # RepairPlan cache: (scope, trigger, treedef, avals, shardings,
        # extra, ruleset digest) -> plan
        self._plan_cache: Dict[Any, Any] = {}
        self.n_traces: int = 0           # compiled-executable trace counter
        # per-rule [nan, inf, events] ledger (int32[n_rules, 3]), fed by
        # every host-dispatched repair pass; see rule_stats()
        self._rule_counts: Optional[jax.Array] = None
        # resolve the rule set once (the config is frozen): every pass,
        # plan, and ledger in this runtime shares this one definition
        self._ruleset: rules_lib.RuleSet = config.ruleset
        self._rules_digest = self._ruleset.digest()
        self.mesh = None
        self.rules = None                # sharding rules (use_mesh), NOT repair rules
        if mesh is not None:
            self.use_mesh(mesh, sharding_rules)

    @property
    def ruleset(self) -> rules_lib.RuleSet:
        """The repair ``RuleSet`` this runtime resolves every pass from."""
        return self._ruleset

    # ------------------------------------------------------------------ mesh
    def use_mesh(self, mesh: Any, rules: Any = None) -> "ApproxSpace":
        """Attach a device mesh + logical-axis rules to this runtime.

        The mesh handle is what makes the space *mesh-native*: repair plans
        derive their placement from it (per-shard local scrub, stats reduced
        globally), the serving pool uses it to register page-axis shardings,
        and compiled executables are cached per sharding layout.  Changing
        the mesh invalidates the plan cache (executables are specialized to
        device placements); the region cache survives (classification is
        placement-independent).
        """
        from ..distributed import sharding as sh  # deferred: keep layering thin

        if mesh is not self.mesh:
            self._plan_cache.clear()
        self.mesh = mesh
        self.rules = rules if rules is not None else sh.rules_for_mesh(mesh)
        return self

    # ------------------------------------------------------------------ plans
    def plan_for(
        self,
        tree: Any,
        *,
        scope: str = "tree",
        ber: Optional[float] = None,
        trigger: str = "forced",
        regions: Any = None,
    ):
        """The ``RepairPlan`` for one (scope, trigger, state layout) pair —
        cached by ``(scope, trigger, treedef, avals, shardings, rule-set
        digest)`` so each distinct layout × rule-set traces its compiled
        executable exactly once (README §Distributed repair).  ``regions``
        overrides the space's cached region tree (the autopilot campaign's
        per-group injection masks); its leaves join the cache key."""
        from . import plan as plan_lib  # deferred: plan builds on us

        return plan_lib.plan_for(
            self, tree, scope=scope, ber=ber, trigger=trigger, regions=regions
        )

    # ---------------------------------------------------------------- regions
    def rules_for(self, tree: Any) -> Tuple[Any, Any]:
        """``(rule_tree, index_tree)`` — the per-leaf ``RepairRule``
        assignment for ``tree``, cached by treedef (path matching depends
        only on tree structure).  The planner compiles executables against
        this assignment; indices key the per-rule counter ledger."""
        treedef = jax.tree_util.tree_structure(tree)
        hit = self._rule_cache.get(treedef)
        if hit is None:
            hit = self.ruleset.assign(tree)
            self._rule_cache[treedef] = hit
        return hit

    def regions_for(self, tree: Any) -> Any:
        """Region pytree for ``tree``, cached by treedef.

        Region classification depends only on tree *structure* (key paths),
        so equal treedefs share one cached region tree — `annotate` no longer
        reruns per step build or per scrub call.

        Exact-island rules (``RepairRule.exact_rule``) override the region
        to EXACT: "exact via stronger correction" is just another rule, and
        it removes the leaf from injection and repair alike.
        """
        treedef = jax.tree_util.tree_structure(tree)
        hit = self._region_cache.get(treedef)
        if hit is None:
            hit = regions_lib.annotate(tree, self.config.region_rules)
            rule_tree, _ = self.rules_for(tree)
            hit = jax.tree_util.tree_map(
                lambda region, rule: (
                    regions_lib.Region.EXACT if rule.exact else region
                ),
                hit, rule_tree,
            )
            self._region_cache[treedef] = hit
        return hit

    def region_bytes(self, tree: Any) -> Tuple[int, int]:
        """(approx_bytes, exact_bytes) of ``tree`` under this space's rules."""
        return regions_lib.count_bytes(tree, self.regions_for(tree))

    # ------------------------------------------------------------- rule swap
    def set_rules(self, ruleset: rules_lib.RuleSet) -> "ApproxSpace":
        """Swap in a new repair ``RuleSet`` at runtime — the autopilot
        guard's tightening mechanism (README §Autopilot).

        Every derived structure keyed on the rule set is invalidated: the
        per-leaf rule/region assignment caches, the plan cache (executables
        close over detectors and fills), and the rules digest.  The per-rule
        counter ledger survives when the label layout is unchanged (the
        guard only *replaces* rules in place, keeping labels/positions, so
        observed-rate windows stay comparable across a tighten); a layout
        change resets it.
        """
        old_labels = self._ruleset.labels()
        self.config = dataclasses.replace(self.config, rules=ruleset)
        self._ruleset = self.config.ruleset
        self._rules_digest = self._ruleset.digest()
        self._rule_cache.clear()
        self._region_cache.clear()
        self._plan_cache.clear()
        if (
            self._rule_counts is not None
            and self._ruleset.labels() != old_labels
        ):
            self._rule_counts = None
        return self

    # ------------------------------------------------------------ mechanisms
    def use(
        self,
        x: jax.Array,
        stats: Optional[stats_lib.Stats] = None,
        *,
        path: str = "",
    ):
        """Register-mode read (§3.3): repair at the consumption site.

        Identity outside register mode, unless an *on-read* rule is bound
        (README §RepairRule — its leaves repair here and only here).
        ``path`` binds the ruleset's exact per-path rule instead of the
        pathless read rule (see ``use_tensor``).  Pure form with
        ``stats``; the convenience form records into ``self.stats``
        (host-side only).
        """
        if stats is not None:
            return use_tensor(x, self.config, stats, path)
        fixed, self.stats = use_tensor(x, self.config, self.stats, path)
        return fixed

    def scrub(
        self,
        tree: Any,
        stats: Optional[stats_lib.Stats] = None,
        *,
        donate: bool = False,
        trigger: str = "forced",
    ):
        """Memory-mode repair + functional write-back (§3.4).

        Pure form with ``stats``; the convenience form records into
        ``self.stats`` (host-side only).

        Called with concrete arrays (the host-side boundary: checkpoint
        save, pool scrubs, injection windows) this dispatches the plan's
        jit-compiled executable — traced once per (treedef, avals,
        shardings), run in place thereafter; ``donate=True`` donates the
        input buffers (safe only when the returned tree *replaces* the
        caller's resident state).  Called under an enclosing jit (tracers,
        e.g. inside ``wrap_train_step``) it inlines into the caller's trace.

        ``trigger`` tags the pass for rule gating (README §RepairRule):
        scheduled callers pass "boundary"/"interval"/"reactive"; the default
        "forced" is an explicit request that every non-exact rule honors.
        """
        if _has_tracers(tree):
            rule_tree, index_tree = self.rules_for(tree)
            out, delta, _ = scrub_tree_rules(
                tree, self.config, stats_lib.zeros(), self.regions_for(tree),
                rule_tree, index_tree, self.ruleset.n_rules, trigger,
            )
        else:
            plan = self.plan_for(tree, scope="tree", trigger=trigger)
            out, delta = plan.run(tree, donate=donate)
            self.scrubbed_bytes += plan.bytes_per_run
        return self._thread_stats(out, delta, stats)

    def scrub_pages(
        self,
        tree: Any,
        page_ids: Any,
        stats: Optional[stats_lib.Stats] = None,
        *,
        donate: bool = False,
        trigger: str = "forced",
    ):
        """Targeted memory-mode repair of rows ``page_ids`` along the leading
        (page) axis of every approximate-region float leaf — the serving
        engine's page-granular scrub (repair only the pages that faulted,
        README §Serving engine).  Same pure/convenience split as ``scrub``,
        same ``trigger`` tagging (the page repair manager passes
        "reactive").

        The compiled path buckets the id count to the next power of two
        (padding with duplicates whose counts are masked), so the number of
        distinct executables stays logarithmic in the pool size instead of
        linear in the faulted-page count.
        """
        if _has_tracers(tree):
            rule_tree, index_tree = self.rules_for(tree)
            out, delta, _ = scrub_pages_tree_rules(
                tree, page_ids, self.config, stats_lib.zeros(),
                self.regions_for(tree), rule_tree, index_tree,
                self.ruleset.n_rules, trigger,
            )
        else:
            ids = np.asarray(page_ids, np.int32).reshape(-1)
            if ids.size == 0 or self.config.mode != "memory":
                return self._thread_stats(tree, stats_lib.zeros(), stats)
            plan = self.plan_for(tree, scope="pages", trigger=trigger)
            out, delta = plan.run(tree, page_ids=ids, donate=donate)
            self.scrubbed_bytes += int(ids.size) * plan.page_row_bytes
        return self._thread_stats(out, delta, stats)

    def scrub_with_reference(
        self,
        tree: Any,
        ref_tree: Any,
        stats: Optional[stats_lib.Stats] = None,
        *,
        donate: bool = False,
    ):
        """``last_checkpoint`` repair (README §Policies): replace fatal lanes
        of approximate-region leaves with values from ``ref_tree`` (e.g. the
        latest checkpoint) — exact restoration for frozen weights.  Runs in
        every repair mode (an explicit reference repair is always a request,
        README §Checkpointing — a forced pass under rule gating); only
        ``tree`` is ever donated."""
        if _has_tracers(tree) or _has_tracers(ref_tree):
            out, delta = reference_scrub_tree(
                tree, ref_tree, stats_lib.zeros(), self.regions_for(tree),
                cfg=self.config,
            )
        else:
            plan = self.plan_for(tree, scope="reference")
            out, delta = plan.run(tree, reference=ref_tree, donate=donate)
            self.scrubbed_bytes += plan.bytes_per_run
        return self._thread_stats(out, delta, stats)

    def _thread_stats(self, out, delta, stats):
        """Merge a functional delta into the caller's stream (pure form) or
        the space's host stream (convenience form)."""
        if stats is None:
            self.stats = stats_lib.merge(self.stats, delta)
            return out
        return out, stats_lib.merge(stats, delta)

    # ------------------------------------------------------------- injection
    def inject(
        self,
        tree: Any,
        key: jax.Array,
        ber: Optional[float] = None,
        *,
        stats: Optional[stats_lib.Stats] = None,
        record: bool = True,
        donate: bool = False,
        regions: Any = None,
    ) -> Tuple[Any, Any]:
        """Simulation boundary: one approximate-memory window of bit flips
        over the approximate region of ``tree``.

        ``ber`` defaults to the config's refresh-model BER.  This is the ONE
        injection/stat entry point shared by train (``inject_state``) and
        serve (the engine's step): pass ``stats`` to thread the ground-truth
        flip count into that stream — returns ``(flipped_tree, stats')``.
        Without ``stats`` it returns ``(flipped_tree, n_flips)`` and records
        into ``self.stats`` unless ``record=False``.  Host-side only —
        injection runs *between* production steps, exactly as physical
        flips would; the compiled executable (cached per layout, donated
        buffers with ``donate=True``) flips shard-locally and reduces the
        flip count globally, never per-replica.

        ``regions`` overrides the space's region tree (same treedef) — the
        autopilot campaign passes a masked region tree to confine one
        window's flips to a single region group.  Flip masks are
        bit-identical across the compiled and eager paths for a given
        (tree, key, ber, regions): both funnel through ``inject_tree``,
        which splits ``key`` once per *leaf position*, so masking a leaf
        EXACT never shifts the keys the remaining leaves draw.
        """
        ber = self.config.resolved_ber if ber is None else ber
        region_tree = self.regions_for(tree) if regions is None else regions
        if ber <= 0.0 or _has_tracers(tree):
            out, flips = inject_tree(tree, key, ber, region_tree)
        else:
            plan = self.plan_for(
                tree, scope="inject", ber=ber, regions=regions
            )
            out, flips = plan.run(tree, key=key, donate=donate)
        if stats is not None:
            return out, stats_lib.record_flips(stats, flips)
        if record:
            self.stats = stats_lib.record_flips(self.stats, flips)
        return out, flips

    # ----------------------------------------------------------------- stats
    def record(self, delta: stats_lib.Stats) -> stats_lib.Stats:
        """Merge a functional stats delta (e.g. from a wrapped step) into the
        unified stream.  Returns the updated totals."""
        self.stats = stats_lib.merge(self.stats, delta)
        return self.stats

    def record_kernel(self, counts: jax.Array) -> stats_lib.Stats:
        """Fold a Pallas kernel counter vector (``kernels.ops`` int32[8]
        ``MM_*``/``AT_*`` layout) into the unified stream — fused-kernel
        repair events finally reach the Table-3 analogue."""
        self.stats = stats_lib.record_kernel_counts(self.stats, counts)
        return self.stats

    def record_rule_counts(self, rule_counts: Any) -> None:
        """Fold one pass's per-rule [nan, inf, events] delta (int32[n_rules,
        3], from a rule-parameterized executable) into the per-rule ledger.
        Accumulation stays lazy (jnp adds); ``rule_stats()`` materializes."""
        if self._rule_counts is None:
            self._rule_counts = jnp.zeros(
                (self.ruleset.n_rules, 3), jnp.int32
            )
        self._rule_counts = self._rule_counts + rule_counts

    def rule_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-rule counters: ``{rule label: {nan_found, inf_found,
        events}}`` over every host-dispatched repair pass (boundary scrubs
        inlined into an enclosing jit contribute to the aggregate stream
        only — per-rule vectors cannot escape a trace)."""
        labels = self.ruleset.labels()
        if self._rule_counts is None:
            rc = np.zeros((len(labels), 3), np.int64)
        else:
            rc = np.asarray(self._rule_counts)
        return {
            label: {
                "nan_found": int(rc[i, 0]),
                "inf_found": int(rc[i, 1]),
                "events": int(rc[i, 2]),
            }
            for i, label in enumerate(labels)
        }

    def stats_dict(self) -> Dict[str, int]:
        return stats_lib.as_dict(self.stats)

    def reset_stats(self) -> None:
        self.stats = stats_lib.zeros()
        self._rule_counts = None

    # ------------------------------------------------------ step decorators
    def wrap_train_step(self, fn: Callable) -> Callable:
        """Install the boundary scrub around a raw train step.

        ``fn(state, batch) -> (state, metrics)`` is the pure compute step
        over the canonical train state ``{"params", "opt", "stats", ...}``.
        In memory mode (with boundary scrubbing scheduled) the wrapper scrubs
        params + optimizer state in one pass at the step boundary — the
        memory-repairing write-back — threading the event counters through
        ``state["stats"]``.  The wrapped step stays pure/jittable.

        Event semantics: one boundary scrub == at most one ``events``
        increment per step, even when both a param and a moment lane were
        fatal (the pre-runtime code ran two scrub passes and could count
        two).  ``nan_found``/``inf_found`` lane totals are unchanged.

        Per-rule counters (README §RepairRule): rule vectors cannot escape
        a trace, so a state carrying a ``"rule_counts"`` entry (int32
        [n_rules, 3], created by ``launch.train.init_train_state(...,
        space=...)``) accumulates each boundary scrub's per-rule
        [nan, inf, events] delta *in the jitted state* — ``train_loop``
        folds it into ``space.rule_stats()`` host-side, closing the gap
        where in-jit boundary scrubs fed only the aggregate stream.
        """

        def step(state, batch):
            if self.config.mode == "memory" and self.config.scrub.boundary:
                resident = {"params": state["params"], "opt": state["opt"]}
                if "rule_counts" in state:
                    rule_tree, index_tree = self.rules_for(resident)
                    resident, stats, rc = scrub_tree_rules(
                        resident, self.config, state["stats"],
                        self.regions_for(resident), rule_tree, index_tree,
                        self.ruleset.n_rules, "boundary",
                    )
                    state = {
                        **state,
                        "params": resident["params"],
                        "opt": resident["opt"],
                        "stats": stats,
                        "rule_counts": state["rule_counts"] + rc,
                    }
                else:
                    resident, stats = self.scrub(
                        resident, state["stats"], trigger="boundary"
                    )
                    state = {
                        **state,
                        "params": resident["params"],
                        "opt": resident["opt"],
                        "stats": stats,
                    }
            return fn(state, batch)

        return step

    def wrap_serve_step(self, fn: Callable) -> Callable:
        """Install the boundary scrub around a raw serve step.

        ``fn(params, cache, batch, pos) -> (*outs, cache)`` with the decode
        cache as the last output.  The wrapped step takes and returns an
        explicit stats stream:

            step(params, cache, batch, pos, stats)
                -> (*outs, cache, stats)

        In memory mode the resident cache is scrubbed at the step boundary
        (clean reads inside the step); in register mode the model's use-site
        repairs run inside ``fn`` and the scrub is skipped.
        """

        def step(params, cache, batch, pos, stats):
            if self.config.mode == "memory" and self.config.scrub.boundary:
                cache, stats = self.scrub(cache, stats, trigger="boundary")
            out = fn(params, cache, batch, pos)
            return (*out, stats)

        return step
