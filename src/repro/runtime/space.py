"""`ApproxSpace` — the single runtime object owning approximate memory.

One `ApproxSpace` owns everything the paper's runtime service needs across
train / serve / checkpoint:

  * **regions** — the exact/approximate partition of every state pytree it
    has seen, cached by treedef (region classification is a pure function of
    tree structure, so it is computed once per structure, not once per call);
  * **stats** — one unified event stream (`core.stats`), including the Pallas
    kernel counter vectors (`kernels.ops.MM_*` / `AT_*`), so fused-kernel
    repairs land in the same Table-3 analogue as the jnp-level mechanisms;
  * **the paper's two mechanisms** — `use(x)` (register mode, §3.3: repair at
    every consumption) and `scrub(tree)` (memory mode, §3.4: repair once at
    the origin, functional write-back);
  * **the simulation boundary** — `inject(tree, key)` is the only entry point
    through which simulated bit flips reach runtime state, and it returns /
    records the ground-truth flip count;
  * **step decorators** — `wrap_train_step` / `wrap_serve_step` install the
    boundary scrub so launch builders stay thin.

Functional/stateful split: every mechanism has a pure form (pass `stats`,
get `(value, stats')` back — safe under jit, this is what the step wrappers
use) and a convenience form (omit `stats`; the event deltas accumulate into
the space's host-side `self.stats`).  Never use the convenience form inside
a jitted function — it would capture tracers.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import detect, injection as injection_lib
from ..core import regions as regions_lib
from ..core import stats as stats_lib
from .config import ApproxConfig, ScrubSchedule

__all__ = [
    "ApproxSpace", "scrub_tree", "scrub_pages_tree", "inject_tree",
    "use_tensor",
]


def _is_approx_float(leaf, region) -> bool:
    return (
        region is regions_lib.Region.APPROX
        and hasattr(leaf, "dtype")
        and jnp.issubdtype(leaf.dtype, jnp.floating)
    )


# ---------------------------------------------------------------------------
# Pytree-level mechanism implementations (the legacy core.repair pytree
# functions are thin shims over these).
# ---------------------------------------------------------------------------


def scrub_tree(
    tree: Any,
    cfg: Any,                       # ApproxConfig or legacy RepairConfig
    stats: stats_lib.Stats,
    region_tree: Any,
) -> Tuple[Any, stats_lib.Stats]:
    """Memory-mode repair of every approximate-region float leaf of ``tree``.

    The returned tree *replaces* the resident state (functional write-back;
    in-place under jit with donated buffers).  Exact-region and non-float
    leaves pass through untouched.  No-op outside memory mode.
    """
    from ..core.repair import repair_tensor  # deferred: repair shims us

    if cfg.mode != "memory":
        return tree, stats
    policy = cfg.resolved_policy()

    nan_tot = jnp.zeros((), jnp.int32)
    inf_tot = jnp.zeros((), jnp.int32)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    region_leaves = jax.tree.leaves(region_tree)
    assert len(leaves) == len(region_leaves), "region tree structure mismatch"

    fixed_leaves = []
    for leaf, region in zip(leaves, region_leaves):
        if _is_approx_float(leaf, region):
            fixed, n, i = repair_tensor(
                leaf, policy=policy, include_inf=cfg.include_inf,
                max_magnitude=cfg.max_magnitude,
            )
            nan_tot = nan_tot + n
            inf_tot = inf_tot + i
            fixed_leaves.append(fixed)
        else:
            fixed_leaves.append(leaf)

    out = jax.tree_util.tree_unflatten(treedef, fixed_leaves)
    return out, stats_lib.record_repair(stats, nan_tot, inf_tot)


def scrub_pages_tree(
    tree: Any,
    page_ids: jax.Array,            # i32[n] rows of the leading (page) axis
    cfg: Any,                       # ApproxConfig or legacy RepairConfig
    stats: stats_lib.Stats,
    region_tree: Any,
) -> Tuple[Any, stats_lib.Stats]:
    """Targeted memory-mode repair: only rows ``page_ids`` along the LEADING
    axis of every approximate-region float leaf are repaired and written back
    (functional ``.at[pages].set``).  This is the page-granular half of the
    paper's reactive design — scrub exactly the pages that faulted instead of
    the whole resident tree.  Duplicate page ids are idempotent (the same
    repaired rows are written twice).  No-op outside memory mode.

    The caller guarantees every approximate float leaf shares one leading
    page axis (the serving KV pool layout, ``Model.paged_cache_defs``).
    """
    from ..core.repair import repair_tensor  # deferred: repair shims us

    if cfg.mode != "memory":
        return tree, stats
    page_ids = jnp.asarray(page_ids, jnp.int32)
    policy = cfg.resolved_policy()

    nan_tot = jnp.zeros((), jnp.int32)
    inf_tot = jnp.zeros((), jnp.int32)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    region_leaves = jax.tree.leaves(region_tree)
    assert len(leaves) == len(region_leaves), "region tree structure mismatch"

    fixed_leaves = []
    for leaf, region in zip(leaves, region_leaves):
        if _is_approx_float(leaf, region):
            rows = leaf[page_ids]
            fixed, n, i = repair_tensor(
                rows, policy=policy, include_inf=cfg.include_inf,
                max_magnitude=cfg.max_magnitude,
            )
            nan_tot = nan_tot + n
            inf_tot = inf_tot + i
            fixed_leaves.append(leaf.at[page_ids].set(fixed.astype(leaf.dtype)))
        else:
            fixed_leaves.append(leaf)

    out = jax.tree_util.tree_unflatten(treedef, fixed_leaves)
    return out, stats_lib.record_repair(stats, nan_tot, inf_tot)


def use_tensor(
    x: jax.Array,
    cfg: Any,                       # ApproxConfig or legacy RepairConfig
    stats: stats_lib.Stats,
) -> Tuple[jax.Array, stats_lib.Stats]:
    """Register-mode read (§3.3): repair at the consumption site.

    Identity outside register mode (memory mode relies on the scrubbed
    buffer, so per-use work would be pure overhead — exactly the paper's
    argument for the memory-repairing mechanism).  Pure; safe under jit.
    """
    from ..core.repair import repair_tensor  # deferred: repair shims us

    if cfg.mode != "register":
        return x, stats
    fixed, n, i = repair_tensor(
        x,
        policy=cfg.resolved_policy(),
        include_inf=cfg.include_inf,
        max_magnitude=cfg.max_magnitude,
    )
    return fixed, stats_lib.record_repair(stats, n, i)


def _leaf_flip_count(before: jax.Array, after: jax.Array) -> jax.Array:
    """Ground-truth bits-flipped between two same-shape float arrays."""
    delta = detect.bits_of(before) ^ detect.bits_of(after)
    return jnp.sum(
        jax.lax.population_count(delta).astype(jnp.int32)
    )


def inject_tree(
    tree: Any,
    key: jax.Array,
    ber: float,
    region_tree: Any,
) -> Tuple[Any, jax.Array]:
    """One approximate-memory window of bit flips over the approximate-region
    leaves (simulation only).  Returns ``(flipped_tree, n_flips)`` where
    ``n_flips`` is the ground-truth number of bits that actually changed
    (collisions fold by XOR, exactly as two physical flips would)."""
    zero = jnp.zeros((), jnp.int32)
    if ber <= 0.0:
        return tree, zero

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    region_leaves = jax.tree.leaves(region_tree)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    flips = zero
    for leaf, region, k in zip(leaves, region_leaves, keys):
        if _is_approx_float(leaf, region):
            flipped = injection_lib.flip_bits(k, leaf, ber)
            flips = flips + _leaf_flip_count(leaf, flipped)
            out.append(flipped)
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out), flips


# ---------------------------------------------------------------------------
# The space.
# ---------------------------------------------------------------------------


class ApproxSpace:
    """The runtime service over one approximate-memory deployment.

    Construct from an ``ApproxConfig``, a legacy ``RepairConfig``, or field
    overrides::

        space = ApproxSpace(ApproxConfig(mode="memory", policy="zero"))
        space = ApproxSpace(model.cfg.repair)          # legacy lift
        space = ApproxSpace(mode="register")           # field shorthand
    """

    def __init__(self, config: Any = None, **overrides):
        if config is None:
            config = ApproxConfig(**overrides)
        else:
            config = ApproxConfig.from_legacy(config, **overrides)
        self.config: ApproxConfig = config
        self.stats: stats_lib.Stats = stats_lib.zeros()
        self._region_cache: Dict[Any, Any] = {}

    # ---------------------------------------------------------------- regions
    def regions_for(self, tree: Any) -> Any:
        """Region pytree for ``tree``, cached by treedef.

        Region classification depends only on tree *structure* (key paths),
        so equal treedefs share one cached region tree — `annotate` no longer
        reruns per step build or per scrub call.
        """
        treedef = jax.tree_util.tree_structure(tree)
        hit = self._region_cache.get(treedef)
        if hit is None:
            hit = regions_lib.annotate(tree, self.config.region_rules)
            self._region_cache[treedef] = hit
        return hit

    def region_bytes(self, tree: Any) -> Tuple[int, int]:
        """(approx_bytes, exact_bytes) of ``tree`` under this space's rules."""
        return regions_lib.count_bytes(tree, self.regions_for(tree))

    # ------------------------------------------------------------ mechanisms
    def use(self, x: jax.Array, stats: Optional[stats_lib.Stats] = None):
        """Register-mode read (§3.3): repair at the consumption site.

        Identity outside register mode.  Pure form with ``stats``; the
        convenience form records into ``self.stats`` (host-side only).
        """
        if stats is not None:
            return use_tensor(x, self.config, stats)
        if self.config.mode != "register":
            return x
        fixed, self.stats = use_tensor(x, self.config, self.stats)
        return fixed

    def scrub(self, tree: Any, stats: Optional[stats_lib.Stats] = None):
        """Memory-mode repair + functional write-back (§3.4).

        Pure form with ``stats``; the convenience form records into
        ``self.stats`` (host-side only).
        """
        out, delta_stats = scrub_tree(
            tree,
            self.config,
            stats if stats is not None else stats_lib.zeros(),
            self.regions_for(tree),
        )
        if stats is None:
            self.stats = stats_lib.merge(self.stats, delta_stats)
            return out
        return out, delta_stats

    def scrub_pages(
        self,
        tree: Any,
        page_ids: Any,
        stats: Optional[stats_lib.Stats] = None,
    ):
        """Targeted memory-mode repair of rows ``page_ids`` along the leading
        (page) axis of every approximate-region float leaf — the serving
        engine's page-granular scrub (repair only the pages that faulted,
        README §Serving engine).  Same pure/convenience split as ``scrub``.
        """
        out, delta_stats = scrub_pages_tree(
            tree,
            page_ids,
            self.config,
            stats if stats is not None else stats_lib.zeros(),
            self.regions_for(tree),
        )
        if stats is None:
            self.stats = stats_lib.merge(self.stats, delta_stats)
            return out
        return out, delta_stats

    def scrub_with_reference(
        self,
        tree: Any,
        ref_tree: Any,
        stats: Optional[stats_lib.Stats] = None,
    ):
        """``last_checkpoint`` repair (README §Policies): replace fatal lanes
        of approximate-region leaves with values from ``ref_tree`` (e.g. the
        latest checkpoint) — exact restoration for frozen weights."""
        from ..core import checkpoint_repair  # deferred: it imports core pkg

        out, delta_stats = checkpoint_repair.scrub_with_reference(
            tree,
            ref_tree,
            stats if stats is not None else stats_lib.zeros(),
            self.regions_for(tree),
            include_inf=self.config.include_inf,
        )
        if stats is None:
            self.stats = stats_lib.merge(self.stats, delta_stats)
            return out
        return out, delta_stats

    # ------------------------------------------------------------- injection
    def inject(
        self,
        tree: Any,
        key: jax.Array,
        ber: Optional[float] = None,
        *,
        record: bool = True,
    ) -> Tuple[Any, jax.Array]:
        """Simulation boundary: one approximate-memory window of bit flips
        over the approximate region of ``tree``.

        ``ber`` defaults to the config's refresh-model BER.  Returns
        ``(flipped_tree, n_flips)`` and records the ground-truth flip count
        into the unified stats (the previously-dead ``flips`` counter).
        Pass ``record=False`` when the caller threads ``n_flips`` into its
        own stats stream (e.g. the train state's) — recording in both would
        double-count on a later ``space.record`` merge.  Host-side only —
        injection runs *between* production steps, exactly as physical
        flips would.
        """
        ber = self.config.resolved_ber if ber is None else ber
        out, flips = inject_tree(tree, key, ber, self.regions_for(tree))
        if record:
            self.stats = stats_lib.record_flips(self.stats, flips)
        return out, flips

    # ----------------------------------------------------------------- stats
    def record(self, delta: stats_lib.Stats) -> stats_lib.Stats:
        """Merge a functional stats delta (e.g. from a wrapped step) into the
        unified stream.  Returns the updated totals."""
        self.stats = stats_lib.merge(self.stats, delta)
        return self.stats

    def record_kernel(self, counts: jax.Array) -> stats_lib.Stats:
        """Fold a Pallas kernel counter vector (``kernels.ops`` int32[8]
        ``MM_*``/``AT_*`` layout) into the unified stream — fused-kernel
        repair events finally reach the Table-3 analogue."""
        self.stats = stats_lib.record_kernel_counts(self.stats, counts)
        return self.stats

    def stats_dict(self) -> Dict[str, int]:
        return stats_lib.as_dict(self.stats)

    def reset_stats(self) -> None:
        self.stats = stats_lib.zeros()

    # ------------------------------------------------------ step decorators
    def wrap_train_step(self, fn: Callable) -> Callable:
        """Install the boundary scrub around a raw train step.

        ``fn(state, batch) -> (state, metrics)`` is the pure compute step
        over the canonical train state ``{"params", "opt", "stats", ...}``.
        In memory mode (with boundary scrubbing scheduled) the wrapper scrubs
        params + optimizer state in one pass at the step boundary — the
        memory-repairing write-back — threading the event counters through
        ``state["stats"]``.  The wrapped step stays pure/jittable.

        Event semantics: one boundary scrub == at most one ``events``
        increment per step, even when both a param and a moment lane were
        fatal (the pre-runtime code ran two scrub passes and could count
        two).  ``nan_found``/``inf_found`` lane totals are unchanged.
        """

        def step(state, batch):
            if self.config.mode == "memory" and self.config.scrub.boundary:
                resident = {"params": state["params"], "opt": state["opt"]}
                resident, stats = self.scrub(resident, state["stats"])
                state = {
                    **state,
                    "params": resident["params"],
                    "opt": resident["opt"],
                    "stats": stats,
                }
            return fn(state, batch)

        return step

    def wrap_serve_step(self, fn: Callable) -> Callable:
        """Install the boundary scrub around a raw serve step.

        ``fn(params, cache, batch, pos) -> (*outs, cache)`` with the decode
        cache as the last output.  The wrapped step takes and returns an
        explicit stats stream:

            step(params, cache, batch, pos, stats)
                -> (*outs, cache, stats)

        In memory mode the resident cache is scrubbed at the step boundary
        (clean reads inside the step); in register mode the model's use-site
        repairs run inside ``fn`` and the scrub is skipped.
        """

        def step(params, cache, batch, pos, stats):
            if self.config.mode == "memory" and self.config.scrub.boundary:
                cache, stats = self.scrub(cache, stats)
            out = fn(params, cache, batch, pos)
            return (*out, stats)

        return step
