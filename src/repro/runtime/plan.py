"""`RepairPlan` — one planner for every repair pass across train / serve /
checkpoint.

Before this module the runtime had three parallel repair paths that each
re-decided what to repair and how: the train boundary scrub (whole resident
tree), the serving page scrub (rows of the pool's leading page axis), and
the checkpoint-reference repair (replace fatal lanes from a known-good
copy).  EDEN's observation — approximate-memory error handling must follow
the physical partition of the resident data — means every one of those
decisions also depends on *placement*: a sharded state must be repaired
shard-locally (no gather) with its counters reduced globally.

`RepairPlan` centralizes both decisions:

  scope       what one pass covers —
                "none"       no-op (repair mode "off" / non-memory modes)
                "tree"       every approximate-region float leaf
                "pages"      rows ``page_ids`` of the leading page axis
                "reference"  fatal lanes replaced from a reference tree
                "inject"     the simulation boundary (bit-flip window)
  placement   where it runs —
                "local"      single-device (or fully replicated) buffers
                "sharded"    ≥1 leaf carries a multi-device NamedSharding;
                             the executable repairs each shard in place
                             under GSPMD and reduces counters globally
                "kernel"     tree- and pages-scope scrubs lower through the
                             Pallas kernels (``kernels/scrub.py`` per leaf;
                             ``scrub_sharded`` for multi-device tree
                             leaves; pages scope is local-placement only —
                             the page gather has no shard_map entry) — the
                             in-place HBM path on real TPUs.  Selected
                             when the backend is TPU (or
                             ``REPRO_KERNEL_PLANS=1`` forces it,
                             interpret-mode on CPU) AND every firing
                             rule's fill maps bit-identically onto a
                             kernel fill (``kernels.common.kernel_fill``)
                             with an encodable detector (pages scope also
                             needs ndim ≥ 2 per repaired leaf for the
                             padding-duplicate count mask); anything else
                             keeps the jnp lowering — never a silent
                             numeric drift.  Lane counters are
                             bit-identical to the jnp path (events stay
                             pass-level, computed from the lane totals).

and owns the compiled executable for the pair.  Plans are cached on the
space by ``(scope, treedef, avals, shardings)`` — one *trace* per state
layout (``ApproxSpace.n_traces`` counts them; asserted in tests), then the
cached executable runs in place with donated buffers.  Stat outputs are
*deltas* (merged host-side), so re-entering with a differently-placed stats
stream can never force a retrace.

Page scrubs bucket their id count to the next power of two: padding entries
duplicate real ids — duplicates scatter identical repaired rows (determin-
istic) and are masked out of the lane counts — so the executable count
stays logarithmic in the pool size instead of linear in faulted pages.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import regions as regions_lib
from ..core import stats as stats_lib
from . import space as space_lib

__all__ = [
    "RepairPlan", "plan_for", "serving_scope", "kernel_plans_enabled",
    "SCOPES",
]

SCOPES = ("none", "tree", "pages", "reference", "inject")

# serving repair-mode knob (ServingConfig.repair) -> plan scope: the ONE
# place the whole-cache-vs-faulted-pages decision lives (the serving
# PageRepairManager routes through this; acceptance — no repair-decision
# logic outside runtime/).
_SERVING_SCOPE = {"off": "none", "whole": "tree", "page": "pages"}


def serving_scope(repair_mode: str) -> str:
    """Map the serving repair mode ("off" | "whole" | "page") to the plan
    scope that implements it."""
    try:
        return _SERVING_SCOPE[repair_mode]
    except KeyError:
        raise ValueError(f"bad serving repair mode {repair_mode!r}") from None


def _sharding_of(leaf) -> Any:
    return getattr(leaf, "sharding", None)


def _placement(shardings: Tuple[Any, ...]) -> str:
    for s in shardings:
        if s is not None and getattr(s, "num_devices", 1) > 1:
            return "sharded"
    return "local"


def kernel_plans_enabled() -> bool:
    """Should tree-scope scrub plans lower through the Pallas kernels?

    ``REPRO_KERNEL_PLANS=1`` forces it (CPU tests run the kernels in
    interpret mode), ``=0`` forces it off; otherwise the kernels engage
    exactly where they are native — a real TPU backend, where the scrub is
    an in-place HBM pass instead of an XLA-fused copy."""
    env = os.environ.get("REPRO_KERNEL_PLANS", "").strip().lower()
    if env in ("1", "true", "yes"):
        return True
    if env in ("0", "false", "no"):
        return False
    return jax.default_backend() == "tpu"


def _kernel_eligible(leaves, regions, rule_tree, trigger, scope="tree") -> bool:
    """Every leaf this pass repairs must map onto the kernel path with
    bit-identical semantics: a ``kernel_fill``-representable fill and a
    detector that encodes into the int32[8] scalar operand.  Zero-size
    leaves pass through (nothing to repair) and do not disqualify.
    Pages-scope passes additionally need ndim ≥ 2 on every repaired leaf:
    the kernel's padding-duplicate count mask is a folded-2D *row* bound
    (``scrub_pages`` ``n_valid``), which a 1-D page axis cannot express."""
    from ..kernels import common as kernels_common

    for leaf, region, rule in zip(
        leaves, jax.tree.leaves(regions), jax.tree.leaves(rule_tree)
    ):
        if not space_lib._is_approx_float(leaf, region):
            continue
        if not rule.fires(trigger) or not getattr(leaf, "size", 0):
            continue
        if kernels_common.kernel_fill(rule.fill) is None:
            return False
        if scope == "pages" and getattr(leaf, "ndim", 0) < 2:
            return False
        try:
            rule.detect.constants(leaf.dtype)
        except (TypeError, ValueError):
            return False
    return True


def _bucket(n: int, cap: int) -> int:
    """Next power of two ≥ n, clamped to the page-axis size."""
    b = 1
    while b < n:
        b <<= 1
    return max(1, min(b, cap))


@dataclasses.dataclass
class RepairPlan:
    """One planned repair pass: scope + placement + compiled executables.

    Obtained via ``ApproxSpace.plan_for`` (cached); ``run`` executes it over
    a concrete tree and returns ``(tree', delta)`` where ``delta`` is a
    functional stats delta (``inject`` scope returns ``(tree', n_flips)``).

    The plan compiles a *per-leaf rule assignment* (README §RepairRule):
    each leaf's Detector × Fill come from the space's ``RuleSet``, the
    plan's ``trigger`` tag gates which rules fire, and the executable
    returns per-rule [nan, inf, events] deltas that ``run`` folds into the
    space's rule ledger.  The rule-set digest joins the cache key, so one
    executable exists per (layout, rule-set).
    """

    space: Any                       # owning ApproxSpace
    scope: str                       # one of SCOPES
    placement: str                   # "local" | "sharded" | "kernel"
    treedef: Any
    regions: Any
    rule_tree: Any                   # per-leaf RepairRule assignment
    index_tree: Any                  # per-leaf rule index (counter ledger)
    n_rules: int
    trigger: str                     # pass tag for rule gating
    bytes_per_run: int               # approx bytes one full-scope pass touches
    page_row_bytes: int              # approx bytes of one page row (pages scope)
    page_capacity: int               # leading page-axis size (pages scope)
    ber: Optional[float] = None      # inject scope only (static per plan)
    shardings: Tuple[Any, ...] = ()  # per-leaf shardings (kernel placement)
    _execs: Dict[Any, Callable] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------------- run
    def run(
        self,
        tree: Any,
        *,
        page_ids: Optional[np.ndarray] = None,
        reference: Any = None,
        key: Optional[jax.Array] = None,
        donate: bool = False,
    ) -> Tuple[Any, Any]:
        if self.scope == "none":
            zero = (
                jnp.zeros((), jnp.int32)
                if self.ber is not None
                else stats_lib.zeros()
            )
            return tree, zero
        leaves = tuple(jax.tree_util.tree_flatten(tree)[0])
        rule_counts = None
        if self.scope == "tree":
            out, delta, rule_counts = self._exec(("tree", donate))(leaves)
        elif self.scope == "pages":
            ids = np.asarray(page_ids, np.int32).reshape(-1)
            if ids.size == 0:
                return tree, stats_lib.zeros()
            # duplicates in ids are legal (idempotent), so the clamp floor is
            # the id count itself, not just the page-axis size
            bucket = _bucket(ids.size, max(self.page_capacity, ids.size))
            padded = np.full((bucket,), ids[0], np.int32)
            padded[: ids.size] = ids
            out, delta, rule_counts = self._exec(("pages", bucket, donate))(
                leaves,
                jnp.asarray(padded),
                jnp.asarray(ids.size, jnp.int32),
            )
        elif self.scope == "reference":
            refs = tuple(jax.tree_util.tree_flatten(reference)[0])
            out, delta, rule_counts = self._exec(("reference", donate))(
                leaves, refs
            )
        elif self.scope == "inject":
            out, delta = self._exec(("inject", donate))(leaves, key)
        else:  # pragma: no cover
            raise ValueError(f"bad plan scope {self.scope!r}")
        if rule_counts is not None:
            self.space.record_rule_counts(rule_counts)
        return jax.tree_util.tree_unflatten(self.treedef, out), delta

    # ----------------------------------------------------------- executables
    def _exec(self, variant: Tuple) -> Callable:
        fn = self._execs.get(variant)
        if fn is None:
            fn = self._build(variant)
            self._execs[variant] = fn
        return fn

    def _build(self, variant: Tuple) -> Callable:
        space, cfg, treedef, regions = (
            self.space, self.space.config, self.treedef, self.regions,
        )
        rule_tree, index_tree, n_rules, trigger = (
            self.rule_tree, self.index_tree, self.n_rules, self.trigger,
        )
        kind, donate = variant[0], variant[-1]

        def note():
            # trace-time side effect: the executable-cache counter.  Runs
            # once per trace, never per call — asserted in tests.
            space.n_traces += 1

        if kind == "tree" and self.placement == "kernel":
            # the Pallas lowering of the tree scrub: one in-place kernel per
            # firing leaf (scrub_sharded for multi-device leaves), lane
            # counts bit-identical to the jnp path, events pass-level
            region_leaves = jax.tree.leaves(regions)
            rule_leaves = jax.tree.leaves(rule_tree)
            index_leaves = jax.tree.leaves(index_tree)
            shardings = self.shardings
            from ..kernels import common as kernels_common
            from ..kernels.scrub import scrub as kernel_scrub
            from ..kernels.scrub import scrub_sharded as kernel_scrub_sharded

            def fn(leaves):
                note()
                nan_tot = jnp.zeros((), jnp.int32)
                inf_tot = jnp.zeros((), jnp.int32)
                rc = jnp.zeros((n_rules, 2), jnp.int32)
                out = []
                for leaf, region, rule, idx, sh in zip(
                    leaves, region_leaves, rule_leaves, index_leaves,
                    shardings,
                ):
                    if (
                        not space_lib._is_approx_float(leaf, region)
                        or not rule.fires(trigger)
                        or not leaf.size
                    ):
                        out.append(leaf)
                        continue
                    policy, constant = kernels_common.kernel_fill(rule.fill)
                    if sh is not None and getattr(sh, "num_devices", 1) > 1:
                        fixed, counts = kernel_scrub_sharded(
                            leaf, sh.mesh, sh.spec,
                            policy=policy, constant=constant,
                            detector=rule.detect,
                        )
                    else:
                        fixed, counts = kernel_scrub(
                            leaf, policy=policy, constant=constant,
                            detector=rule.detect,
                        )
                    nan_tot = nan_tot + counts[0]
                    inf_tot = inf_tot + counts[1]
                    rc = rc.at[idx, 0].add(counts[0]).at[idx, 1].add(counts[1])
                    out.append(fixed)
                delta = stats_lib.record_repair(
                    stats_lib.zeros(), nan_tot, inf_tot
                )
                return tuple(out), delta, space_lib._finish_rule_counts(rc)

        elif kind == "tree":

            def fn(leaves):
                note()
                tree = jax.tree_util.tree_unflatten(treedef, leaves)
                out, delta, rc = space_lib.scrub_tree_rules(
                    tree, cfg, stats_lib.zeros(), regions,
                    rule_tree, index_tree, n_rules, trigger,
                )
                return tuple(jax.tree_util.tree_flatten(out)[0]), delta, rc

        elif kind == "pages" and self.placement == "kernel":
            # the Pallas lowering of the page scrub: gather→kernel→scatter
            # per firing leaf (kernels/scrub.scrub_pages), the bucketed id
            # vector's padding duplicates masked out of the lane counts by
            # the kernel's n_valid row bound — counts bit-identical to the
            # jnp path, events pass-level
            region_leaves = jax.tree.leaves(regions)
            rule_leaves = jax.tree.leaves(rule_tree)
            index_leaves = jax.tree.leaves(index_tree)
            from ..kernels import common as kernels_common
            from ..kernels.scrub import scrub_pages as kernel_scrub_pages

            def fn(leaves, page_ids, n_valid):
                note()
                nan_tot = jnp.zeros((), jnp.int32)
                inf_tot = jnp.zeros((), jnp.int32)
                rc = jnp.zeros((n_rules, 2), jnp.int32)
                out = []
                for leaf, region, rule, idx in zip(
                    leaves, region_leaves, rule_leaves, index_leaves
                ):
                    if (
                        not space_lib._is_approx_float(leaf, region)
                        or not rule.fires(trigger)
                        or not leaf.size
                    ):
                        out.append(leaf)
                        continue
                    policy, constant = kernels_common.kernel_fill(rule.fill)
                    fixed, counts = kernel_scrub_pages(
                        leaf, page_ids, policy=policy, constant=constant,
                        detector=rule.detect, n_valid=n_valid,
                    )
                    nan_tot = nan_tot + counts[0]
                    inf_tot = inf_tot + counts[1]
                    rc = rc.at[idx, 0].add(counts[0]).at[idx, 1].add(counts[1])
                    out.append(fixed)
                delta = stats_lib.record_repair(
                    stats_lib.zeros(), nan_tot, inf_tot
                )
                return tuple(out), delta, space_lib._finish_rule_counts(rc)

        elif kind == "pages":

            def fn(leaves, page_ids, n_valid):
                note()
                tree = jax.tree_util.tree_unflatten(treedef, leaves)
                out, delta, rc = space_lib.scrub_pages_tree_rules(
                    tree, page_ids, cfg, stats_lib.zeros(), regions,
                    rule_tree, index_tree, n_rules, trigger,
                    n_valid=n_valid,
                )
                return tuple(jax.tree_util.tree_flatten(out)[0]), delta, rc

        elif kind == "reference":

            def fn(leaves, refs):
                note()
                tree = jax.tree_util.tree_unflatten(treedef, leaves)
                ref = jax.tree_util.tree_unflatten(treedef, refs)
                out, delta, rc = space_lib.reference_scrub_tree_rules(
                    tree, ref, stats_lib.zeros(), regions,
                    rule_tree, index_tree, n_rules,
                )
                return tuple(jax.tree_util.tree_flatten(out)[0]), delta, rc

        elif kind == "inject":
            ber = self.ber

            def fn(leaves, key):
                note()
                tree = jax.tree_util.tree_unflatten(treedef, leaves)
                out, flips = space_lib.inject_tree(tree, key, ber, regions)
                return tuple(jax.tree_util.tree_flatten(out)[0]), flips

        else:  # pragma: no cover
            raise ValueError(f"bad executable kind {kind!r}")

        return jax.jit(fn, donate_argnums=(0,) if donate else ())


# ---------------------------------------------------------------------------
# The planner.
# ---------------------------------------------------------------------------


def plan_for(
    space: Any,
    tree: Any,
    *,
    scope: str = "tree",
    ber: Optional[float] = None,
    trigger: str = "forced",
    regions: Any = None,
) -> RepairPlan:
    """Plan one repair pass over ``tree`` for ``space``.

    Scope resolution: "tree" and "pages" are memory-mode mechanisms — in any
    other repair mode they resolve to the "none" no-op plan (matching the
    eager tree functions' mode gate).  "reference" always runs (an explicit
    reference repair is a request, not a schedule), and "inject" always runs
    (the simulation boundary is mode-independent).  Placement is derived
    from the leaves' shardings: any multi-device NamedSharding makes the
    plan shard-local.

    ``trigger`` tags the pass for rule gating (README §RepairRule): only
    rules whose trigger fires on this tag repair their leaves, so one
    (layout, trigger) pair is one executable.  The rule-set digest joins the
    cache key; reference/inject scopes ignore the trigger (forced /
    mode-independent respectively).

    ``regions`` overrides the space's cached region tree (same treedef) —
    the autopilot campaign's per-group injection masks.  The override's
    leaf values join the cache key, so each distinct mask compiles its own
    executable and masks never alias each other's plans.
    """
    if scope not in SCOPES:
        raise ValueError(f"bad plan scope {scope!r}; expected one of {SCOPES}")
    if scope in ("tree", "pages") and space.config.mode != "memory":
        scope = "none"
    if scope not in ("tree", "pages"):
        trigger = "forced"

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    # non-array leaves (plain python scalars in user trees) key by type and
    # pass through the executable untouched, as they did on the eager path
    avals = tuple(
        (
            tuple(getattr(leaf, "shape", ())),
            str(getattr(leaf, "dtype", type(leaf).__name__)),
        )
        for leaf in leaves
    )
    shardings = tuple(_sharding_of(leaf) for leaf in leaves)
    extra = float(ber) if scope == "inject" else None
    kernels_on = kernel_plans_enabled()
    regions_key = (
        None if regions is None else tuple(jax.tree.leaves(regions))
    )
    key = (
        scope, trigger, treedef, avals, shardings, extra,
        space._rules_digest, kernels_on, regions_key,
    )

    plan = space._plan_cache.get(key)
    if plan is not None:
        return plan

    if regions is None:
        regions = space.regions_for(tree)
    rule_tree, index_tree = space.rules_for(tree)
    placement = _placement(shardings)
    if (
        scope == "tree"
        and kernels_on
        and _kernel_eligible(leaves, regions, rule_tree, trigger)
    ):
        placement = "kernel"
    elif (
        scope == "pages"
        and kernels_on
        and placement == "local"   # no shard_map entry for the page gather
        and _kernel_eligible(leaves, regions, rule_tree, trigger, scope)
    ):
        placement = "kernel"
    region_leaves = jax.tree.leaves(regions)
    rule_leaves = jax.tree.leaves(rule_tree)
    approx_bytes = 0
    page_row_bytes = 0
    page_capacity = 0
    for leaf, region, rule in zip(leaves, region_leaves, rule_leaves):
        if not space_lib._is_approx_float(leaf, region):
            continue
        if scope in ("tree", "pages") and not rule.fires(trigger):
            continue    # the ledger counts only what this pass repairs
        nbytes = leaf.size * leaf.dtype.itemsize
        approx_bytes += nbytes
        if leaf.ndim >= 1 and leaf.shape[0]:
            page_row_bytes += nbytes // leaf.shape[0]
            page_capacity = (
                leaf.shape[0] if page_capacity == 0
                else min(page_capacity, leaf.shape[0])
            )

    plan = RepairPlan(
        space=space,
        scope=scope,
        placement=placement,
        treedef=treedef,
        regions=regions,
        rule_tree=rule_tree,
        index_tree=index_tree,
        n_rules=space.ruleset.n_rules,
        trigger=trigger,
        bytes_per_run=0 if scope == "none" else approx_bytes,
        page_row_bytes=page_row_bytes,
        page_capacity=max(page_capacity, 1),
        ber=extra,
        shardings=shardings,
    )
    space._plan_cache[key] = plan
    return plan
