"""`ApproxConfig` — the single frozen configuration of the approximate-memory
runtime.

The paper's deployment has exactly one knob surface: which memory is
approximate (regions), how broken it is (refresh -> BER), how errors are
repaired (mode + policy), and when the memory-repairing mechanism runs (the
scrub schedule).  EDEN and the approximate-computing survey both observe that
such systems live or die by keeping this a *single* coherent configuration;
previously ours was scattered over `core.repair.RepairConfig`,
`core.injection.ApproxMemoryModel`, ad-hoc region rules, and per-call-site
scrub cadences.  `ApproxConfig` merges all four.

`ApproxConfig` is attribute-compatible with the legacy `RepairConfig`
(`mode` / `policy` / `include_inf` / `max_magnitude`), so every consumer that
only reads those fields (`nn/layers.py`, `core.repair.use`, model configs)
accepts either object unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

from ..core import injection as injection_lib
from ..core import policies as policies_lib
from ..core import regions as regions_lib
from ..core import rules as rules_lib

_MODES = ("off", "register", "memory")


@dataclasses.dataclass(frozen=True)
class ScrubSchedule:
    """When the memory-repairing mechanism runs.

    boundary   scrub resident state at every step boundary (the paper's
               write-back point for training; README §Scrub schedule)
    interval   additionally scrub every ``interval`` steps/tokens (serving
               cadence; 0 disables the periodic pass)
    """

    boundary: bool = True
    interval: int = 0

    def due(self, t: int) -> bool:
        """Host-side periodic-scrub predicate for step/token counter ``t``."""
        return bool(self.interval) and t % self.interval == 0


@dataclasses.dataclass(frozen=True)
class AutopilotConfig:
    """The online-guard contract emitted by the autopilot frontier solver
    (README §Autopilot).

    The profiling campaign measures, per rule label, how many fatal events
    the detector is *expected* to report per step at the assigned refresh
    point.  The guard watches ``ApproxSpace.rule_stats()`` deltas per
    ``window`` steps and compares observed counts against
    ``tolerance × expected × window + floor``; ``patience`` consecutive
    over-threshold windows tighten the drifting group's rule one stage
    (stricter detector/trigger, then demotion to the exact-ECC rule), and
    ``cooldown`` windows must pass before the same group can be tightened
    again — the hysteresis that keeps one noisy window from cascading.

      window     steps per observation window
      tolerance  multiplier over the profiled expectation before a strike
      floor      absolute event slack added to every threshold (guards the
                 expected≈0 labels against single-event trips)
      patience   consecutive over-threshold windows before tightening
      cooldown   windows to ignore a label after tightening it
      expected   ordered (rule label, expected fatal events per step)
    """

    window: int = 8
    tolerance: float = 4.0
    floor: float = 4.0
    patience: int = 2
    cooldown: int = 2
    expected: Tuple[Tuple[str, float], ...] = ()

    def __post_init__(self):
        if self.window <= 0:
            raise ValueError("autopilot window must be positive")
        if self.patience <= 0:
            raise ValueError("autopilot patience must be positive")
        if isinstance(self.expected, dict):
            object.__setattr__(
                self, "expected", tuple(sorted(self.expected.items()))
            )

    def expected_rate(self, label: str) -> float:
        """Profiled fatal events per step for ``label`` (0.0 if unknown)."""
        for name, rate in self.expected:
            if name == label:
                return float(rate)
        return 0.0

    def threshold(self, label: str) -> float:
        """Observed events per window above this are a strike."""
        return (
            self.tolerance * self.expected_rate(label) * self.window
            + self.floor
        )


@dataclasses.dataclass(frozen=True)
class ApproxConfig:
    """One frozen config owning repair, injection, regions, and scheduling.

    Repair (legacy ``RepairConfig`` fields, attribute-compatible):
      mode             "off" | "register" | "memory"
      policy           repair-value policy (name | float | RepairPolicy)
      include_inf      treat ±Inf as fatal too
      max_magnitude    beyond-paper extension (README §Config): also treat
                       |x| ≥ threshold as fatal — required for training
                       under sustained BER

    Approximate-memory model (simulation boundary):
      refresh_interval_s   the refresh-relaxation point; resolves to a BER
                           and an energy saving via the literature anchors
                           in ``core.injection``
      ber                  explicit BER override (None -> from refresh)

    Regions:
      region_rules     ordered (regex, Region) rules partitioning state
                       pytrees into exact/approximate memory

    Schedule:
      scrub            when the memory-repairing mechanism runs

    Rules (README §RepairRule):
      rules            an explicit ``RuleSet`` binding per-region
                       Detector × Fill × Trigger rules to tree paths.
                       ``None`` (the default) lifts the scalar repair
                       fields above into a one-rule set — the legacy
                       single-knob behavior, bit for bit.  When ``rules``
                       is given it is the single source of truth for
                       detection/fill/trigger; the scalar fields remain as
                       attribute-compatible defaults for path-free reads
                       (``use()``) and shim delegation.
    """

    mode: str = "memory"
    policy: Any = "neighbor_mean"
    include_inf: bool = True
    max_magnitude: Optional[float] = None

    refresh_interval_s: float = 1.0            # Flikker point (BER ~1e-6)
    ber: Optional[float] = None

    region_rules: Tuple[Tuple[str, regions_lib.Region], ...] = (
        regions_lib.DEFAULT_RULES
    )
    scrub: ScrubSchedule = ScrubSchedule()
    rules: Optional[rules_lib.RuleSet] = None
    # Online guard contract (README §Autopilot).  None disables the guard;
    # an AutopilotConfig arms it in train_loop (serving has its own switch
    # on ServingConfig.autopilot).
    autopilot: Optional[AutopilotConfig] = None

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"bad repair mode {self.mode!r}")
        if isinstance(self.rules, (tuple, list)):
            # accept raw (pattern, rule) bindings for config ergonomics
            object.__setattr__(
                self, "rules", rules_lib.RuleSet(tuple(self.rules))
            )

    @property
    def ruleset(self) -> rules_lib.RuleSet:
        """The effective rule set: explicit ``rules`` or the one-rule lift
        of the scalar repair fields (legacy compatibility)."""
        if self.rules is not None:
            return self.rules
        return rules_lib.RuleSet.from_legacy(self)

    # ------------------------------------------------------------- resolution
    def resolved_policy(self) -> policies_lib.RepairPolicy:
        return policies_lib.get(self.policy)

    @property
    def memory_model(self) -> injection_lib.ApproxMemoryModel:
        """The refresh/BER/energy point this config simulates."""
        return injection_lib.ApproxMemoryModel.from_refresh(
            self.refresh_interval_s
        )

    @property
    def resolved_ber(self) -> float:
        return self.ber if self.ber is not None else self.memory_model.ber

    def expected_faults(
        self, n_bytes: int, windows: float, ber: Optional[float] = None
    ) -> float:
        """Expected fatal-bit count accumulated by ``n_bytes`` of approximate
        memory after dwelling ``windows`` refresh windows (the EDEN
        refresh→BER relationship, charged over time).

        The per-window BER is memoryless — each relaxed-refresh window flips
        a bit with probability ``ber`` independently — so the expectation is
        linear in dwell time: ``bits × ber × windows``.  This is what the
        serving prefix cache charges against a page's *dwell clock* (steps
        since its last scrub) to decide whether a cache hit must scrub
        before the page is re-shared (README §Serving engine).  ``ber``
        defaults to the config's refresh-model BER; pass the serving
        engine's simulation BER to charge what the pool actually sees.
        """
        b = self.resolved_ber if ber is None else ber
        return float(n_bytes) * 8.0 * float(b) * max(float(windows), 0.0)

    # ------------------------------------------------------------ conversion
    @staticmethod
    def from_legacy(cfg: Any, **overrides) -> "ApproxConfig":
        """Lift a legacy ``RepairConfig`` (or any object with its four
        fields, including an ``ApproxConfig``) into an ``ApproxConfig``."""
        if isinstance(cfg, ApproxConfig):
            return dataclasses.replace(cfg, **overrides) if overrides else cfg
        fields = dict(
            mode=cfg.mode,
            policy=cfg.policy,
            include_inf=cfg.include_inf,
            max_magnitude=getattr(cfg, "max_magnitude", None),
        )
        fields.update(overrides)
        return ApproxConfig(**fields)

    def legacy(self):
        """The equivalent legacy ``RepairConfig`` (for shim delegation)."""
        from ..core.repair import RepairConfig  # deferred: repair shims us

        return RepairConfig(
            mode=self.mode,
            policy=self.policy,
            include_inf=self.include_inf,
            max_magnitude=self.max_magnitude,
        )

    def memory_forced(self) -> "ApproxConfig":
        """Same config with mode pinned to "memory" — the save-scrub and
        cache-scrub paths always run the memory-repairing mechanism even
        when the run itself is register-mode or off."""
        return dataclasses.replace(self, mode="memory")
