"""Approximate-memory runtime — the paper's technique as one coherent
service (README §Runtime).

  ApproxConfig    one frozen config: repair mode/policy, refresh→BER point,
                  region rules, scrub schedule
  ScrubSchedule   when the memory-repairing mechanism runs
  ApproxSpace     the runtime object owning regions (cached by treedef), the
                  unified stats stream (incl. Pallas kernel counters), the
                  paper's two mechanisms (`use`/`scrub`), the simulation
                  boundary (`inject`), and the train/serve step decorators

The legacy surface (`core.repair.use` / `scrub_pytree` / `inject_pytree`,
`launch.serve.scrub_cache`) delegates here; new code should construct an
``ApproxSpace`` directly.
"""
from .config import ApproxConfig, ScrubSchedule  # noqa: F401
from .space import (  # noqa: F401
    ApproxSpace,
    inject_tree,
    scrub_pages_tree,
    scrub_tree,
)

__all__ = [
    "ApproxConfig",
    "ApproxSpace",
    "ScrubSchedule",
    "inject_tree",
    "scrub_pages_tree",
    "scrub_tree",
]
