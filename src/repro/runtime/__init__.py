"""Approximate-memory runtime — the paper's technique as one coherent
service (README §Runtime / §Distributed repair).

  ApproxConfig    one frozen config: repair mode/policy, refresh→BER point,
                  region rules, scrub schedule, and (README §RepairRule) an
                  optional RuleSet of per-region Detector × Fill × Trigger
                  rules — scalar knobs lift into a one-rule set
  Detector /      the rule grammar (re-exported from core.rules): which
  RepairRule /    stored patterns are fatal, what value repairs them, which
  RuleSet         passes fire, bound to tree paths by ordered regexes
  ScrubSchedule   when the memory-repairing mechanism runs
  ApproxSpace     the runtime object owning regions (cached by treedef), the
                  unified stats stream (incl. Pallas kernel counters), the
                  paper's two mechanisms (`use`/`scrub`), the simulation
                  boundary (`inject`), the train/serve step decorators, and
                  — via `use_mesh` — the device mesh the repair pipeline
                  runs on
  RepairPlan      one planner for every repair pass (train boundary scrub,
                  serving page scrub, checkpoint-reference repair, the
                  injection window): scope + placement + the jit-compiled
                  donated executable, cached per (treedef, avals, shardings)

The legacy surface (`core.repair.scrub_pytree` / `inject_pytree`,
`core.checkpoint_repair.scrub_with_reference`, `launch.serve.scrub_cache`)
delegates here and warns; new code should construct an ``ApproxSpace``
directly.
"""
from ..core.rules import Detector, RepairRule, RuleSet  # noqa: F401
from .config import ApproxConfig, AutopilotConfig, ScrubSchedule  # noqa: F401
from .space import (  # noqa: F401
    ApproxSpace,
    inject_tree,
    reference_scrub_tree,
    scrub_pages_tree,
    scrub_tree,
)
from .plan import RepairPlan, serving_scope  # noqa: F401

__all__ = [
    "ApproxConfig",
    "ApproxSpace",
    "AutopilotConfig",
    "Detector",
    "RepairPlan",
    "RepairRule",
    "RuleSet",
    "ScrubSchedule",
    "inject_tree",
    "reference_scrub_tree",
    "scrub_pages_tree",
    "scrub_tree",
    "serving_scope",
]
