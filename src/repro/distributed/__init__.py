from .sharding import (  # noqa: F401
    RULES_SINGLE_POD,
    RULES_MULTI_POD,
    rules_for_mesh,
    spec_for_leaf,
    tree_shardings,
)
from .compression import (  # noqa: F401
    compress_int8,
    decompress_int8,
    compressed_allreduce_tree,
)
