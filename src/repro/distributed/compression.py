"""Gradient compression: int8-quantized all-reduce with error feedback.

Moves the collective roofline term ~4× down (bf16→int8 on the wire) for
collective-bound cells (§Perf).  Off by default — it changes numerics; the
error-feedback residual makes the *accumulated* quantization error decay
(standard EF-SGD result), which the convergence test verifies.

Scheme (per gradient leaf, per step):
    e      — carried f32 residual (same shape as the leaf)
    x      = g + e                      (inject the carried error)
    scale  = max|x| / 127               (per-leaf symmetric scale)
    q      = round(x / scale) ∈ int8
    ĝ      = psum(q) · scale / n        (the compressed mean)
    e'     = x − q·scale                (what quantization dropped)

The psum runs on int8 payload (the 4× wire saving); scales are f32 scalars
all-reduced alongside (negligible bytes).  When no mesh/axis is given the
collective degrades to identity (single-host testing).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(q_int8, scale_f32, new_err) with error feedback."""
    xf = x.astype(jnp.float32) + err
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_err = xf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_allreduce_tree(
    grads: Any,
    err_tree: Any,
    axis_name: Optional[str] = None,
) -> Tuple[Any, Any]:
    """Mean-all-reduce a gradient pytree with int8 payload + error feedback.

    Returns (mean_grads_f32, new_err_tree).  ``axis_name`` names the mapped
    axis inside shard_map/pmap; None (testing) reduces over nothing.
    """
    def one(g, e):
        q, scale, new_e = compress_int8(g, e)
        if axis_name is not None:
            n = jax.lax.psum(1, axis_name)
            # int8 summation overflows at >127 summands of ±127; widen the
            # *wire* payload stays int8, the reduce accumulates in i32.
            s = jax.lax.psum(q.astype(jnp.int32), axis_name)
            scale_sum = jax.lax.psum(scale, axis_name)
            # each shard used its own scale: approximate with the mean scale
            ghat = s.astype(jnp.float32) * (scale_sum / n) / n
        else:
            ghat = decompress_int8(q, scale)
        return ghat, new_e

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree.leaves(err_tree)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    ghat = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return ghat, new_e


def init_error_tree(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
