"""Logical-axis sharding: rules table → PartitionSpec trees (MaxText-style).

Every parameter/cache/activation dimension carries a *logical* axis name
(nn/module.py ParamDef.axes).  One rules table maps logical names to mesh
axes; changing the parallelism strategy is a table edit, not a model edit.

Default rules (README §Sharding):

  batch    → (pod, data)    activations/batch dims: pure DP across pods
  embed    → data           FSDP/ZeRO-3: params + Adam moments sharded over
                            the data axis, all-gathered per layer by GSPMD
  heads/kv/mlp/vocab/expert → model   (tensor parallelism)
  kv_seq   → None           (overridable to model for decode cells — the
                            KV cache is the dominant resident there and
                            n_kv is often < model axis size)
  layers   → None           (scan dimension — never sharded)

Validation: a dim is sharded only if its size divides the mesh-axis size;
otherwise the spec silently degrades to replicated-on-that-dim, which is the
GSPMD-compatible fallback (it matters for e.g. n_kv=2 on a model=16 axis).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# logical axis -> mesh axis (or tuple of mesh axes)
RULES_SINGLE_POD: Dict[str, MeshAxes] = {
    "batch": "data",
    "embed": "data",
    "heads": "model",
    "kv": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "kv_seq": None,
    "layers": None,
    # serving KV-pool page axis (pool.py): pages spread over the DP axis so
    # page scrubs repair device-local rows — repair granularity follows the
    # sharding (README §Distributed repair)
    "page": "data",
    # activation dims (with_sharding_constraint sites inside the models)
    "act_batch": "data",
    "act_seq": None,          # "model" enables sequence parallelism
    "act_embed": None,
    "act_heads": "model",
    "act_vocab": "model",
    "act_expert": "model",
}

RULES_MULTI_POD: Dict[str, MeshAxes] = dict(
    RULES_SINGLE_POD,
    batch=("pod", "data"),
    act_batch=("pod", "data"),
)


# ---------------------------------------------------------------------------
# Activation sharding constraints (MaxText-style).
#
# XLA's sharding propagation loses the batch sharding through the embedding
# gather and across scan boundaries (observed: attention compute replicated
# over the data axis — a 16× FLOP regression in the dry-run).  Models call
# ``constrain(x, ("act_batch", "act_seq", ...))`` at layer boundaries; when a
# (mesh, rules) context is active this lowers to with_sharding_constraint,
# otherwise it is the identity (single-device tests/examples).
# ---------------------------------------------------------------------------

_ACTIVE: list = []   # stack of (mesh, rules)


class use_rules:
    """Context manager activating (mesh, rules) for ``constrain`` sites."""

    def __init__(self, mesh: Mesh, rules: Dict[str, MeshAxes]):
        self.pair = (mesh, rules)

    def __enter__(self):
        _ACTIVE.append(self.pair)
        return self.pair

    def __exit__(self, *exc):
        _ACTIVE.pop()
        return False


def active_rules():
    return _ACTIVE[-1] if _ACTIVE else None


def constrain(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """Constrain an activation's sharding by logical dim names (no-op when no
    rules context is active)."""
    if not _ACTIVE:
        return x
    mesh, rules = _ACTIVE[-1]
    spec = spec_for_leaf(
        logical, x.shape, mesh, rules, unconstrained_default=True
    )
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def rules_for_mesh(mesh: Mesh, overrides: Optional[Dict[str, MeshAxes]] = None):
    base = RULES_MULTI_POD if "pod" in mesh.axis_names else RULES_SINGLE_POD
    if overrides:
        base = dict(base, **overrides)
    return base


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def spec_for_leaf(
    logical_axes: Optional[Sequence[Optional[str]]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Dict[str, MeshAxes],
    *,
    unconstrained_default: bool = False,
) -> P:
    """PartitionSpec for one leaf, with divisibility validation.

    ``unconstrained_default=True`` (activation-constraint mode): dims that do
    not resolve to a shardable mesh axis become P.UNCONSTRAINED instead of
    replicated — a with_sharding_constraint must never *forbid* XLA from
    sharding a dim we merely didn't name (a forced-replicated score tensor
    costs an all-gather; observed 2.7e11 wire bytes on qwen2 train_4k).
    """
    if logical_axes is None:
        return P()
    assert len(logical_axes) == len(shape), (logical_axes, shape)
    fallback = P.UNCONSTRAINED if unconstrained_default else None
    used: set = set()
    parts = []
    for name, dim in zip(logical_axes, shape):
        axes = rules.get(name) if name is not None else None
        if axes is None:
            parts.append(fallback)
            continue
        key = tuple(axes) if isinstance(axes, tuple) else (axes,)
        if any(a in used for a in key):
            # a mesh axis may appear once per spec; later dims degrade
            parts.append(fallback)
            continue
        if dim % _axis_size(mesh, axes) != 0:
            parts.append(fallback)    # degrade: leave to the partitioner
            continue
        used.update(key)
        parts.append(axes)
    return P(*parts)


def tree_shardings(
    abstract_tree: Any,
    logical_tree: Any,
    mesh: Mesh,
    rules: Dict[str, MeshAxes],
) -> Any:
    """NamedSharding tree matching ``abstract_tree``'s structure.

    ``logical_tree`` has tuples-of-names at the positions where
    ``abstract_tree`` has arrays/ShapeDtypeStructs.  Scalar leaves (step
    counters, rng keys) get fully-replicated specs.
    """
    flat_a, treedef = jax.tree_util.tree_flatten(abstract_tree)

    def _is_axes_leaf(x):
        # axes leaves are None or plain tuples of axis names; namedtuples
        # (OptState!) are pytree nodes, not leaves
        return x is None or (
            isinstance(x, tuple)
            and not hasattr(x, "_fields")
            and all(s is None or isinstance(s, str) for s in x)
        )

    flat_l = jax.tree_util.tree_flatten(logical_tree, is_leaf=_is_axes_leaf)[0]
    assert len(flat_a) == len(flat_l), (
        "logical tree mismatch", len(flat_a), len(flat_l)
    )
    out = []
    for a, l in zip(flat_a, flat_l):
        spec = spec_for_leaf(l, a.shape, mesh, rules)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_sharding(mesh: Mesh, rules: Dict[str, MeshAxes]) -> NamedSharding:
    """Sharding for (B, ...) input batches: batch dim over the DP axes."""
    return NamedSharding(mesh, P(rules["batch"]))


def batch_specs_for_inputs(
    input_tree: Any, mesh: Mesh, rules: Dict[str, MeshAxes]
) -> Any:
    """Batch-dim-sharded NamedShardings for an input_specs dict."""
    bs = rules["batch"]

    def one(leaf):
        nparts = _axis_size(mesh, bs)
        if leaf.shape and leaf.shape[0] % nparts == 0:
            return NamedSharding(mesh, P(bs))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, input_tree)


def bytes_per_device(abstract_tree: Any, shardings: Any) -> int:
    """Lower bound on resident bytes per device for a sharded tree."""
    total = 0
    for a, s in zip(
        jax.tree.leaves(abstract_tree), jax.tree.leaves(shardings)
    ):
        n = int(np.prod(a.shape)) if a.shape else 1
        itemsize = np.dtype(a.dtype).itemsize
        shard_n = n // s.num_devices if s.is_fully_addressable else n
        # NamedSharding: compute shard size from the spec
        shard = s.shard_shape(a.shape) if a.shape else a.shape
        shard_n = int(np.prod(shard)) if shard else 1
        total += shard_n * itemsize
    return total
