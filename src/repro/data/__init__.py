from .pipeline import SyntheticStream, batch_for_step  # noqa: F401
