"""Stateless-seeded synthetic data pipeline.

Fault-tolerance property (README §Checkpointing): the batch for step ``i`` is a pure
function of ``(seed, i)`` — a restarted job resumes from the checkpointed
step with *no data-state replay* and bit-identical batches.  This is the
cheapest correct answer to "data pipeline state in checkpoints" at
1000-node scale: there is none.

The synthetic stream is a Zipf-ish token distribution with local n-gram
structure (so the LM loss actually goes down and convergence tests are
meaningful), plus modality stand-ins for the VLM/audio frontends (the
assignment stubs those to precomputed embeddings).

Host sharding: ``host_slice`` carves the global batch by process index, so a
multi-host launch feeds each host only its shard (simulated single-process
here; the arithmetic is the production one).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig


@partial(jax.jit, static_argnames=("batch", "seq", "vocab"))
def _tokens_for_step(seed: jax.Array, step: jax.Array, batch: int, seq: int,
                     vocab: int) -> jax.Array:
    """Zipf-ish tokens with n-gram structure, deterministic in (seed, step)."""
    key = jax.random.fold_in(jax.random.fold_in(seed, step), 0x7e4)
    k1, k2, k3 = jax.random.split(key, 3)
    # Zipf-ish marginal: exp-distributed rank -> clamp to vocab.
    r = jax.random.exponential(k1, (batch, seq)) * (vocab / 8.0)
    base = jnp.clip(r.astype(jnp.int32), 0, vocab - 1)
    # local structure: with p=0.5 repeat the previous token's neighbourhood
    rep = jax.random.bernoulli(k2, 0.5, (batch, seq))
    shift = jax.random.randint(k3, (batch, seq), -2, 3)
    prev = jnp.roll(base, 1, axis=1)
    structured = jnp.clip(prev + shift, 0, vocab - 1)
    return jnp.where(rep, structured, base)


def batch_for_step(
    cfg: ArchConfig,
    seed: jax.Array,
    step,
    *,
    batch: int,
    seq: int,
) -> Dict[str, jax.Array]:
    """The global batch for one training step (pure in (seed, step))."""
    step = jnp.asarray(step, jnp.int32)
    if cfg.family == "audio":
        tokens = _tokens_for_step(seed, step, batch, seq, cfg.vocab)
        fkey = jax.random.fold_in(jax.random.fold_in(seed, step), 0xF0)
        frames = jax.random.normal(fkey, (batch, seq, cfg.d_model), jnp.float32)
        return {"frames": frames.astype(cfg.dtype), "tokens": tokens}
    if cfg.frontend == "patches":
        P = int(seq * cfg.frontend_fraction)
        tokens = _tokens_for_step(seed, step, batch, seq - P, cfg.vocab)
        pkey = jax.random.fold_in(jax.random.fold_in(seed, step), 0xF1)
        patches = jax.random.normal(pkey, (batch, P, cfg.d_model), jnp.float32)
        return {"tokens": tokens, "patch_embeds": patches.astype(cfg.dtype)}
    return {"tokens": _tokens_for_step(seed, step, batch, seq, cfg.vocab)}


@dataclasses.dataclass(frozen=True)
class SyntheticStream:
    """Iterator facade over batch_for_step with host slicing."""

    cfg: ArchConfig
    seed: int
    batch: int
    seq: int
    process_index: int = 0
    process_count: int = 1

    def __post_init__(self):
        assert self.batch % self.process_count == 0, (
            "global batch must divide across hosts",
            self.batch, self.process_count,
        )

    @property
    def host_batch(self) -> int:
        return self.batch // self.process_count

    def host_slice(self, global_batch: Dict[str, jax.Array]):
        lo = self.process_index * self.host_batch
        return {
            k: jax.lax.dynamic_slice_in_dim(v, lo, self.host_batch, axis=0)
            for k, v in global_batch.items()
        }

    def __call__(self, step) -> Dict[str, jax.Array]:
        g = batch_for_step(
            self.cfg, jax.random.PRNGKey(self.seed), step,
            batch=self.batch, seq=self.seq,
        )
        if self.process_count == 1:
            return g
        return self.host_slice(g)
